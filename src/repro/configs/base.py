"""Config system: architecture configs + registry.

Every assigned architecture is a ``ModelConfig`` instance registered under its
public id (``--arch <id>``). ``ModelConfig.reduced()`` yields the smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) mandated by the spec; the full
config is only ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

_REGISTRY: dict[str, "ModelConfig"] = {}


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation (arXiv / model card)

    # trunk dims
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: Optional[int] = None   # default: d_model // num_heads

    # attention variant
    attention: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    mrope: bool = False              # multimodal rotary (qwen2-vl)
    sliding_window: Optional[int] = None
    local_global_ratio: int = 0      # gemma3: N local layers per 1 global

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    v_head_dim: int = 0              # default: head_dim

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # routed-expert hidden width
    first_dense_layers: int = 0      # leading dense layers (deepseek-v2)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # SSM (mamba2 / rwkv6)
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    hybrid_attn_every: int = 0       # zamba2: attn block period (0 = never)

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    max_source_len: int = 0          # whisper: 1500 mel frames

    # modality frontend stub (vlm/audio) — embeddings arrive precomputed
    frontend: Optional[str] = None   # "vision" | "audio"
    num_frontend_tokens: int = 0

    # misc
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which input shapes this arch supports for long-context decode
    subquadratic: bool = False       # True => long_500k eligible

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.num_heads, 1)

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim if self.v_head_dim else self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + trunk), for roofline."""
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        n += self.num_layers * self._layer_params()
        if self.encoder_layers:
            n += self.encoder_layers * self._encoder_layer_params()
            n += self.max_source_len * d  # learned positions
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k routed + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        moe_layers = self.num_layers - self.first_dense_layers
        dense_layers = self.first_dense_layers
        n += dense_layers * (self._attn_params() + 3 * d * self.d_ff + 2 * d)
        active_ff = (self.num_experts_per_tok + self.num_shared_experts) * self.moe_d_ff
        n += moe_layers * (self._attn_params() + 3 * d * active_ff
                           + d * self.num_experts + 2 * d)
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.attention == "mla":
            r, qr = self.kv_lora_rank, self.q_lora_rank or self.d_model
            rope = self.qk_rope_head_dim
            nh = self.num_heads
            n = d * (r + rope)                       # kv down + k_rope
            n += d * qr + qr * nh * (hd + rope)      # q down/up
            n += r * nh * (hd + self.resolved_v_head_dim)  # kv up
            n += nh * self.resolved_v_head_dim * d   # out proj
            return n
        if self.attention == "none":
            if self.ssm_state_dim and not self.hybrid_attn_every:
                # rwkv6 token-mix: r/k/v/g/o + decay params ~ 5 d^2
                return 5 * d * d + 2 * d
            return 0
        nh, nkv = self.num_heads, self.num_kv_heads
        return d * nh * hd + 2 * d * nkv * hd + nh * hd * d

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            n = self.num_experts * 3 * d * self.moe_d_ff
            n += self.num_shared_experts * 3 * d * self.moe_d_ff
            n += d * self.num_experts  # router
            return n
        return 3 * d * self.d_ff  # swiglu

    def _ssm_params(self) -> int:
        d = self.d_model
        inner = self.ssm_expand * d
        nh = inner // self.ssm_head_dim
        # mamba2: in_proj (z,x,B,C,dt) + conv + out_proj + A,D
        n = d * (2 * inner + 2 * self.ssm_state_dim + nh)
        n += self.ssm_conv_width * (inner + 2 * self.ssm_state_dim)
        n += inner * d + 2 * nh
        return n

    def _layer_params(self) -> int:
        d = self.d_model
        if self.family in ("ssm",) and self.ssm_state_dim:
            # pure mamba-like; rwkv6 handled via attention == none + d_ff
            if self.attention == "none" and self.d_ff:
                return self._attn_params() + 3 * d * self.d_ff + 2 * d
            return self._ssm_params() + 2 * d
        if self.family == "hybrid":
            n = self._ssm_params() + 2 * d
            if self.hybrid_attn_every:
                # amortized shared attention + its ffn
                n += (self._gqa_params() + 3 * d * self.d_ff) // self.hybrid_attn_every
            return n
        if self.is_moe and self.first_dense_layers:
            # average of dense + moe layers
            moe = self.num_layers - self.first_dense_layers
            tot = (self.first_dense_layers * (self._attn_params() + 3 * d * self.d_ff)
                   + moe * (self._attn_params() + self._ffn_params()))
            return tot // self.num_layers + 2 * d
        return self._attn_params() + self._ffn_params() + 2 * d

    def _gqa_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        return d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d

    def _encoder_layer_params(self) -> int:
        d = self.d_model
        return self._gqa_params() + 3 * d * self.d_ff + 2 * d

    # ---- smoke-test reduction ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        nh = min(self.num_heads, 4)
        ratio = max(self.num_heads // max(self.num_kv_heads, 1), 1)
        nkv = max(nh // min(ratio, nh), 1)
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=d // nh,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            q_lora_rank=min(self.q_lora_rank, 64),
            qk_rope_head_dim=min(self.qk_rope_head_dim, 32),
            v_head_dim=0,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            # drop-free capacity so reduced-model tests are batch-invariant
            capacity_factor=1.0e9 if self.num_experts else self.capacity_factor,
            ssm_state_dim=min(self.ssm_state_dim, 16),
            ssm_head_dim=min(self.ssm_head_dim, 32),
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            max_source_len=min(self.max_source_len, 64),
            num_frontend_tokens=min(self.num_frontend_tokens, 16),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            local_global_ratio=min(self.local_global_ratio, 1) if self.local_global_ratio else 0,
        )


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import arch modules lazily to avoid cycles
    from repro.configs import (  # noqa: F401
        qwen2_vl_7b, zamba2_2_7b, minitron_8b, whisper_tiny, qwen2_5_32b,
        rwkv6_7b, dbrx_132b, gemma3_4b, internlm2_1_8b, deepseek_v2_236b,
    )
