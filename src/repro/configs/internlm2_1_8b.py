"""InternLM2-1.8B — dense GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    source="arXiv:2403.17297",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    attention="gqa",
    rope_theta=1.0e6,
    subquadratic=False,
))
