"""Minitron-8B — width-pruned Nemotron-4, dense GQA [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    source="arXiv:2407.14679",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    attention="gqa",
    rope_theta=1.0e4,
    subquadratic=False,
))
