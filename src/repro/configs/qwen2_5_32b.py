"""Qwen2.5-32B — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B scaled]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1.0e6,
    subquadratic=False,
))
