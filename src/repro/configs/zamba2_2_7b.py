"""Zamba2-2.7B — Mamba2 trunk + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,          # shared attention block is full MHA
    d_ff=10240,
    vocab_size=32000,
    attention="gqa",          # used by the shared attention block only
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,      # one shared attn block every 6 mamba2 blocks
    tie_embeddings=True,
    subquadratic=True,        # mamba2 state decode is O(1) -> long_500k runs
))
