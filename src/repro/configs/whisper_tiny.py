"""Whisper-tiny — encoder-decoder ASR backbone [arXiv:2212.04356].

Mel-spectrogram + conv frontend is a STUB per spec: ``input_specs()`` supplies
precomputed frame embeddings (batch, 1500, d_model) to the encoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    attention="gqa",           # MHA (kv == heads)
    qkv_bias=True,
    cross_attention=True,
    max_source_len=1500,
    frontend="audio",
    num_frontend_tokens=1500,
    rope_theta=0.0,            # whisper uses learned positions, not RoPE
    tie_embeddings=True,
    subquadratic=False,
))
