"""Qwen2-VL-7B language backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision encoder (ViT + merger) is a STUB per spec: ``input_specs()`` supplies
precomputed patch embeddings of shape (batch, num_frontend_tokens, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1.0e6,
    mrope=True,
    frontend="vision",
    num_frontend_tokens=256,     # stubbed patch-embedding prefix per sample
    tie_embeddings=False,
    subquadratic=False,          # full attention -> long_500k skipped
))
