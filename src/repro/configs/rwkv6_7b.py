"""RWKV6-7B (Finch) — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,              # rwkv heads (head_dim 64)
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention="none",          # token-mix is the rwkv6 recurrence
    ssm_state_dim=64,          # per-head (head_dim x head_dim) wkv state
    ssm_head_dim=64,
    subquadratic=True,         # O(1) decode state -> long_500k runs
))
