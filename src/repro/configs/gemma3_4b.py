"""Gemma3-4B — 5:1 local(sliding-1024):global attention, 128k [hf:google/gemma-3-1b-pt].

long_500k eligibility: local layers are sliding-window (w=1024); at >=500k the
global layers also fall back to the windowed variant (block-sparse carve noted
in DESIGN.md), keeping decode state sub-quadratic.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    attention="gqa",
    rope_theta=1.0e6,
    sliding_window=1024,
    local_global_ratio=5,      # 5 local : 1 global
    tie_embeddings=True,
    subquadratic=True,         # sliding-window variant -> long_500k runs
))
