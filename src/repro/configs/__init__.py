"""Architecture + shape registry (``--arch <id>``)."""
from repro.configs.base import ModelConfig, get_config, list_archs, register
from repro.configs.shapes import (
    SHAPES, InputShape, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    shape_applicable,
)

__all__ = [
    "ModelConfig", "get_config", "list_archs", "register",
    "SHAPES", "InputShape", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "shape_applicable",
]
