"""Cluster-level discrete-event simulator: replays an arrival trace through a
scheduler, accounting provisioning cost, GPU usage, and SLO attainment
(paper §7.4 testbed replay + §7.5 simulations)."""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cluster import GPUS_PER_NODE, Node, NodeAllocator
from repro.core.group import CoExecutionGroup, Placement, SwitchCosts
from repro.core.job import RLJob


@dataclass
class Report:
    total_cost: float                    # $ integrated over the replay
    avg_cost_per_hour: float
    makespan_h: float
    slo_attained: int
    n_jobs: int
    peak_rollout_gpus: int
    peak_train_gpus: int
    rollout_bubble: float                # time-weighted avg idle fraction
    train_bubble: float
    per_job_slowdown: dict[str, float] = field(default_factory=dict)

    @property
    def slo_rate(self) -> float:
        return self.slo_attained / max(self.n_jobs, 1)


class ClusterSimulator:
    """Replays jobs through any group-based scheduler
    (InterGroupScheduler / SoloDisaggregation / Random / Greedy / Gavel+)."""

    def __init__(self, scheduler, *, migration: bool = True,
                 switch: Optional[SwitchCosts] = SwitchCosts(), seed: int = 0):
        self.sched = scheduler
        self.migration = migration
        self.switch = switch
        self.rng = np.random.default_rng(seed)

    def _group_of(self, jid: str):
        for G in self.sched.groups.values():
            if jid in G.jobs:
                return G
        return None

    def run(self, jobs: list[RLJob]) -> Report:
        jobs = sorted(jobs, key=lambda j: j.arrival)
        jmap = {j.job_id: j for j in jobs}
        atomic = getattr(self.sched, "job_atomic", False)

        seq = [0]

        def nseq() -> int:
            seq[0] += 1
            return seq[0]

        events: list[tuple[float, int, str, str]] = []
        for j in jobs:
            heapq.heappush(events, (j.arrival, nseq(), "arrive", j.job_id))

        iters_total: dict[str, float] = {}
        iters_done: dict[str, float] = {}
        rate: dict[str, float] = {}
        active_time: dict[str, float] = {}
        bubbles: dict[str, tuple[float, float]] = {}   # gid -> (roll, train)
        solo_rate_cache: dict[str, float] = {}

        def solo_rate(job: RLJob) -> float:
            """Realized solo iteration time with the job's own (common-random-
            number) duration draws — the SLO reference."""
            if job.job_id not in solo_rate_cache:
                nr = [Node(f"__sr{i}", self.sched.alloc.rollout_accel)
                      for i in range(job.n_roll_nodes)]
                nt = [Node(f"__st{i}", self.sched.alloc.train_accel)
                      for i in range(job.n_train_nodes)]
                G = CoExecutionGroup("__solo", nr, nt)
                G.add_job(job, Placement(tuple(n.node_id for n in nr)))
                res = G.simulate(stochastic=True, migration=self.migration,
                                 switch=self.switch, work_conserving=True)
                solo_rate_cache[job.job_id] = res.iter_time[job.job_id]
            return solo_rate_cache[job.job_id]

        now = 0.0
        cost = 0.0
        broll_int = btrain_int = nroll_int = ntrain_int = 0.0
        slo_ok: dict[str, bool] = {}
        slowdown: dict[str, float] = {}

        def advance(to: float) -> None:
            nonlocal now, cost, broll_int, btrain_int, nroll_int, ntrain_int
            dt = to - now
            if dt <= 0:
                now = max(now, to)
                return
            cost += self.sched.total_cost_per_hour() * dt / 3600.0
            for G in self.sched.groups.values():
                nroll_int += len(G.rollout_nodes) * dt
                ntrain_int += len(G.train_nodes) * dt
                br, bt = bubbles.get(G.gid, (1.0, 1.0))
                broll_int += br * len(G.rollout_nodes) * dt
                btrain_int += bt * len(G.train_nodes) * dt
            for jid, r in rate.items():
                iters_done[jid] += dt / r
                active_time[jid] += dt
            now = to

        def refresh(G) -> None:
            res = G.simulate(migration=self.migration, switch=self.switch,
                             stochastic=True, job_atomic=atomic,
                             work_conserving=True)
            bubbles[G.gid] = (res.rollout_bubble, res.train_bubble)
            for jid, r in res.iter_time.items():
                rate[jid] = max(r, 1e-6)

        def push_finish(jid: str) -> None:
            rem = (iters_total[jid] - iters_done[jid]) * rate[jid]
            heapq.heappush(events, (now + max(rem, 0.0), nseq(), "finish", jid))

        while events:
            t, _, kind, jid = heapq.heappop(events)
            advance(t)
            if kind == "arrive":
                job = jmap[jid]
                self.sched.schedule(job)
                iters_total[jid] = job.duration / max(solo_rate(job), 1e-6)
                iters_done[jid] = 0.0
                active_time[jid] = 0.0
                G = self._group_of(jid)
                refresh(G)
                for member in G.jobs:
                    push_finish(member)
            else:
                if jid not in rate:
                    continue
                if iters_done[jid] < iters_total[jid] - 1e-6:
                    push_finish(jid)     # stale prediction (rates changed)
                    continue
                job = jmap[jid]
                realized = active_time[jid] / max(iters_done[jid], 1e-9)
                # SLO contract is against the *estimated* solo iteration time
                # (paper §4.2: "T_solo is the estimated iteration time when
                # job k is running alone"), i.e. the worst-case bound used
                # at admission.
                slowdown[jid] = realized / max(job.t_solo, 1e-9)
                slo_ok[jid] = slowdown[jid] <= job.slo * 1.001
                G = self._group_of(jid)
                rate.pop(jid, None)
                self.sched.release(jid)
                if G is not None and G.jobs:
                    refresh(G)
                    for member in G.jobs:
                        push_finish(member)

        makespan_h = now / 3600.0
        return Report(
            total_cost=cost,
            avg_cost_per_hour=cost / max(makespan_h, 1e-9),
            makespan_h=makespan_h,
            slo_attained=sum(slo_ok.values()),
            n_jobs=len(jobs),
            peak_rollout_gpus=self.sched.alloc.peak_rollout * GPUS_PER_NODE,
            peak_train_gpus=self.sched.alloc.peak_train * GPUS_PER_NODE,
            rollout_bubble=broll_int / max(nroll_int, 1e-9),
            train_bubble=btrain_int / max(ntrain_int, 1e-9),
            per_job_slowdown=slowdown)


def group_from_profiles(profiles, *, gid: str = "measured",
                        rollout_nodes: int = 1, train_nodes: int = 1,
                        accel=None, **job_overrides) -> CoExecutionGroup:
    """Build a co-execution group whose job durations are *engine-measured*
    :class:`~repro.core.phase_control.PhaseProfile` records instead of
    modeled worst cases — the feedback path from the execution plane
    (``rl.coexec`` / ``launch.train --mux``) into the planner.

    ``profiles`` is an iterable of PhaseProfiles (e.g. the dict values from
    ``RollMuxRuntime.phase_profiles()``).  All jobs share one rollout
    placement, matching the in-process runtime's single rollout pool.
    """
    from repro.core.cluster import H20, H800

    roll = [Node(f"{gid}-r{i}", accel or H20) for i in range(rollout_nodes)]
    train = [Node(f"{gid}-t{i}", accel or H800) for i in range(train_nodes)]
    G = CoExecutionGroup(gid, roll, train)
    placement = Placement(tuple(n.node_id for n in roll))
    for prof in profiles:
        G.add_job(prof.to_job(**job_overrides), placement)
    return G


def simulate_profiles(profiles, *, work_conserving: bool = True,
                      switch: Optional[SwitchCosts] = None, **group_kw):
    """Run the intra-group DES on measured phase profiles; returns the
    ``SimResult`` whose iter_time / bubble fractions reflect served
    durations.  This is what closes the loop: decisions the simulator
    makes (admission, grouping) can now be checked against — and driven
    by — what the engine actually measured."""
    G = group_from_profiles(profiles, **group_kw)
    return G.simulate(work_conserving=work_conserving, switch=switch)


def replay_verl(jobs: list[RLJob], alloc: NodeAllocator) -> Report:
    """Analytic replay of the colocated veRL baseline: every job runs all
    phases on its own training-pool nodes; rollout pays the HBM-bandwidth
    slowdown of compute GPUs; no rollout pool is billed."""
    slowdown_bw = alloc.rollout_accel.hbm_tbps / alloc.train_accel.hbm_tbps
    t_price = alloc.train_accel.price_per_gpu_hour
    cost = 0.0
    peak_t: list[tuple[float, int]] = []
    slo_ok = 0
    end = 0.0
    for j in jobs:
        iter_co = j.t_roll * slowdown_bw + j.t_train
        life = j.duration * iter_co / j.t_solo
        cost += j.n_train_gpus * t_price * life / 3600.0
        peak_t.append((j.arrival, j.n_train_gpus))
        peak_t.append((j.arrival + life, -j.n_train_gpus))
        slo_ok += iter_co <= j.slo * j.t_solo * 1.001
        end = max(end, j.arrival + life)
    peak = cur = 0
    for _, d in sorted(peak_t):
        cur += d
        peak = max(peak, cur)
    makespan_h = end / 3600.0
    # dependency bubble on the (joint) pool: rollout's compute units idle
    # during memory-bound rollout is a hardware mismatch, not idleness; we
    # report the training-FLOP idle share during rollout as the bubble.
    roll_frac = float(np.mean([j.t_roll * slowdown_bw /
                               (j.t_roll * slowdown_bw + j.t_train)
                               for j in jobs]))
    return Report(
        total_cost=cost, avg_cost_per_hour=cost / max(makespan_h, 1e-9),
        makespan_h=makespan_h, slo_attained=slo_ok, n_jobs=len(jobs),
        peak_rollout_gpus=0, peak_train_gpus=peak,
        rollout_bubble=0.0, train_bubble=roll_frac)
