"""Phase-centric control model (paper §5.1): ``@rollmux.phase`` decorator,
run permits, warm-start state management, and runtime hooks.

The execution plane is in-process: resource pools are permit queues, job
states live in a HostStateCache between phases (device_put back = warm
start), and the intra-group FIFO queues drive the round-robin schedule.

Executed phases leave measured per-phase timelines behind
(:attr:`PermitPool.timeline`); :meth:`RollMuxRuntime.phase_profiles`
distills them into :class:`PhaseProfile` records the co-execution
simulator consumes in place of modeled worst-case durations
(``core.simulator.simulate_profiles``) — served, not modeled, phase times
drive the multiplexing decisions.
"""
from __future__ import annotations

import contextlib
import functools
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.train.checkpoints import HostStateCache


class PermitPool:
    """A resource pool (e.g. 'rollout', 'train') with FIFO run permits —
    the per-worker queue of §5.1."""

    def __init__(self, name: str, capacity: int = 1):
        self.name = name
        self.capacity = capacity
        self._cv = threading.Condition()
        self._queue: deque[int] = deque()
        self._active = 0
        self._ticket = 0
        self.busy_time = 0.0
        self.timeline: list[tuple[str, float, float]] = []  # (who, t0, t1)

    def acquire(self) -> int:
        with self._cv:
            self._ticket += 1
            my = self._ticket
            self._queue.append(my)
            while self._queue[0] != my or self._active >= self.capacity:
                self._cv.wait()
            self._queue.popleft()
            self._active += 1
            return my

    def release(self) -> None:
        with self._cv:
            self._active -= 1
            self._cv.notify_all()

    def resize(self, capacity: int) -> None:
        """Retune the pool's permit count on a live pool (the elastic
        controller's actuator).  Growing wakes waiters immediately; when
        shrinking, permits already held are never revoked — the pool
        simply stops admitting until ``_active`` drains below the new
        capacity (``acquire`` re-checks the bound under the condition
        variable)."""
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        with self._cv:
            self.capacity = capacity
            self._cv.notify_all()

    @property
    def waiting(self) -> int:
        """Tickets queued behind the permit bound (telemetry gauge)."""
        with self._cv:
            return len(self._queue)


@dataclass
class PhaseStats:
    runs: int = 0
    warm_starts: int = 0
    cold_starts: int = 0
    switch_time: float = 0.0
    run_time: float = 0.0
    wait_time: float = 0.0


@dataclass(frozen=True)
class PhaseProfile:
    """Engine-measured per-phase timeline of one job: every executed rollout
    and training phase duration, in order.  This is the bridge from the real
    execution plane to the planner: where ``RLJob`` carries *modeled*
    worst-case durations, a profile carries what the serving engine and
    train step actually took, and :meth:`to_job` turns that into the job
    record the co-execution simulator / admission planner consume
    (worst-case = max observed, runtime stochasticity = observed spread)."""
    job_id: str
    rollout_s: tuple[float, ...] = ()
    train_s: tuple[float, ...] = ()
    # reward-verification phase durations (the third permit pool): empty
    # for executors that verify inline on the critical path; the streaming
    # mux (``rl.stream``) populates it with per-group verifier times.
    reward_s: tuple[float, ...] = ()
    # KV transfer durations (disaggregated prefill->decode hand-over,
    # ``serve.router.DisaggRouter`` under a runtime): empty for monolithic
    # engines.  Transfers sit on the rollout critical path — a handle must
    # be adopted before its decode starts — so ``to_job`` folds the
    # worst-case transfer load into ``t_roll``.
    transfer_s: tuple[float, ...] = ()

    @property
    def t_roll(self) -> float:
        """Worst-case (admission-bound) rollout duration."""
        return max(self.rollout_s, default=0.0)

    @property
    def t_transfer(self) -> float:
        """Worst per-iteration KV-transfer total (many permits per
        iteration — one per adopted handle — hence the chunked max, same
        accounting as reward/train)."""
        return self._worst_iteration_total(self.transfer_s)

    def _worst_iteration_total(self, xs: tuple[float, ...]) -> float:
        """Worst per-*iteration* total of a phase that may take several
        permits per iteration (the streaming executor holds one reward
        permit per GRPO group and one train permit per micro-step).  The
        per-permit durations are in execution order with a uniform count
        per iteration, so chunking them evenly and taking the heaviest
        chunk gives the iteration-level worst case the conservative
        admission planner needs — a plain ``max`` over permits would
        under-report the phase load by the groups-per-iteration factor."""
        if not xs:
            return 0.0
        it = max(self.iterations, 1)
        per = max(-(-len(xs) // it), 1)             # ceil division
        return max(sum(xs[i:i + per])
                   for i in range(0, len(xs), per))

    @property
    def t_train(self) -> float:
        return self._worst_iteration_total(self.train_s)

    @property
    def t_reward(self) -> float:
        return self._worst_iteration_total(self.reward_s)

    @property
    def t_roll_mean(self) -> float:
        return sum(self.rollout_s) / max(len(self.rollout_s), 1)

    @property
    def t_train_mean(self) -> float:
        return sum(self.train_s) / max(len(self.train_s), 1)

    @property
    def t_reward_mean(self) -> float:
        return sum(self.reward_s) / max(len(self.reward_s), 1)

    @property
    def iterations(self) -> int:
        return min(len(self.rollout_s), len(self.train_s))

    def to_job(self, **overrides):
        """Build the ``core.job.RLJob`` this measured profile implies.

        Worst-case phase durations are the observed maxima; the stochastic
        runtime scale spans the observed min/max ratio, so the simulator's
        common-random-number draws reproduce the measured spread."""
        from repro.core.job import RLJob

        lo = 1.0
        if self.rollout_s and self.train_s:
            lo = min(min(self.rollout_s) / max(self.t_roll, 1e-9),
                     min(self.train_s) / max(self.t_train, 1e-9))
        kw = dict(job_id=self.job_id,
                  t_roll=self.t_roll + self.t_transfer,
                  t_train=self.t_train, t_reward=self.t_reward,
                  runtime_scale=(min(lo, 1.0), 1.0))
        kw.update(overrides)
        return RLJob(**kw)


class RollMuxRuntime:
    """In-process execution plane shared by the co-executing jobs."""

    def __init__(self, host_cache_gb: float = 64.0):
        self.pools: dict[str, PermitPool] = {}
        self.cache = HostStateCache(int(host_cache_gb * 2**30))
        self.stats: dict[str, PhaseStats] = {}
        self.hooks: list[Callable[[str, str, str], None]] = []
        self._t0 = time.perf_counter()

    def pool(self, name: str, capacity: int = 1) -> PermitPool:
        if name not in self.pools:
            self.pools[name] = PermitPool(name, capacity)
        return self.pools[name]

    def metrics(self):
        """Unified :class:`~repro.core.telemetry.MetricsSnapshot` of the
        execution plane: per-pool busy fractions (pool busy time over
        runtime elapsed — the elastic controller's permit-retuning
        signal) and capacities.  Merges cleanly with engine/router
        snapshots (dict fields union)."""
        from repro.core.telemetry import MetricsSnapshot
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        return MetricsSnapshot(
            source="runtime",
            pool_busy_frac={name: min(p.busy_time / elapsed, 1.0)
                            for name, p in self.pools.items()},
            pool_capacity={name: p.capacity
                           for name, p in self.pools.items()})

    def runtime_hook(self, fn: Callable) -> Callable:
        """@rollmux.runtime_hook — called as fn(job_id, phase, event)."""
        self.hooks.append(fn)
        return fn

    def _emit(self, job_id: str, phase_name: str, event: str) -> None:
        for h in self.hooks:
            h(job_id, phase_name, event)

    def phase(self, pool: str, name: Optional[str] = None, *,
              init_fn: Optional[Callable] = None):
        """Decorator: wraps a phase function into a schedulable entity.

        The wrapped function signature becomes fn(job_id, *args) and receives
        the job's restored state as first arg: fn(state, *args) -> (state, out).
        State is offloaded to host DRAM after the phase (lightweight
        suspension: the compiled executables — the control plane — stay
        alive, only data-plane arrays move).
        """
        def deco(fn):
            pname = name or fn.__name__

            @functools.wraps(fn)
            def wrapped(job_id: str, *args, **kwargs):
                key = f"{job_id}/{pool}"
                st = self.stats.setdefault(f"{job_id}:{pname}", PhaseStats())
                t_req = time.perf_counter()
                p = self.pool(pool)
                p.acquire()                       # run permit (intra-group FIFO)
                try:
                    t_start = time.perf_counter()
                    st.wait_time += t_start - t_req
                    self._emit(job_id, pname, "start")
                    state, sw = self.cache.restore(key)
                    if state is None:             # cold start
                        t0 = time.perf_counter()
                        if init_fn is None:
                            raise RuntimeError(
                                f"no cached state and no init_fn for {key}")
                        state = init_fn()
                        sw = time.perf_counter() - t0
                        st.cold_starts += 1
                    else:
                        st.warm_starts += 1
                    st.switch_time += sw
                    state, out = fn(state, *args, **kwargs)
                    jax.block_until_ready(jax.tree.leaves(state)[:1])
                    self.cache.offload(key, state)  # suspend: data plane out
                    t_end = time.perf_counter()
                    st.run_time += t_end - t_start
                    st.runs += 1
                    p.timeline.append((f"{job_id}:{pname}", t_start - self._t0,
                                       t_end - self._t0))
                    p.busy_time += t_end - t_start
                    self._emit(job_id, pname, "end")
                    return out
                finally:
                    p.release()

            wrapped.pool_name = pool
            wrapped.phase_name = pname
            return wrapped
        return deco

    @contextlib.contextmanager
    def permit(self, pool: str, who: str, capacity: int = 1):
        """Run-permit scope without the state-offload machinery of
        :meth:`phase`: acquire the pool's FIFO permit, run the body, record
        the busy interval on the pool timeline.  The mux executors use this
        for phases whose state stays in the driver (e.g. the pipelined
        single-job trainer, where params are handed over directly instead
        of through the actor cache)."""
        p = self.pool(pool, capacity)
        p.acquire()
        t_start = time.perf_counter()
        try:
            yield p
        finally:
            t_end = time.perf_counter()
            p.timeline.append((who, t_start - self._t0, t_end - self._t0))
            p.busy_time += t_end - t_start
            p.release()

    def seed_state(self, job_id: str, pool: str, state) -> None:
        """Pre-populate the actor cache (Init phase of the dependency graph)."""
        self.cache.offload(f"{job_id}/{pool}", state)

    def phase_profiles(self, *, rollout_pool: str = "rollout",
                       train_pool: str = "train",
                       reward_pool: str = "reward",
                       transfer_pool: str = "transfer"
                       ) -> dict[str, PhaseProfile]:
        """Distill the executed pool timelines into per-job
        :class:`PhaseProfile` records (measured durations, in execution
        order).  Timeline entries are tagged ``"job:phase"`` by both
        :meth:`phase` and :meth:`permit`.  The reward and transfer pools
        are optional — executors that verify inline / serve monolithically
        never create them and the profiles simply carry no such
        durations (the transfer pool is populated by a
        ``serve.router.DisaggRouter`` given this runtime: each
        prefill→decode KV hand-over takes a permit there)."""
        roll: dict[str, list[float]] = {}
        train: dict[str, list[float]] = {}
        reward: dict[str, list[float]] = {}
        transfer: dict[str, list[float]] = {}
        for pool_name, acc in ((rollout_pool, roll), (train_pool, train),
                               (reward_pool, reward),
                               (transfer_pool, transfer)):
            p = self.pools.get(pool_name)
            if p is None:
                continue
            for who, t0, t1 in p.timeline:
                acc.setdefault(who.split(":")[0], []).append(t1 - t0)
        return {jid: PhaseProfile(jid, tuple(roll.get(jid, ())),
                                  tuple(train.get(jid, ())),
                                  tuple(reward.get(jid, ())),
                                  tuple(transfer.get(jid, ())))
                for jid in sorted(set(roll) | set(train) | set(reward)
                                  | set(transfer))}
