"""Phase-centric control model (paper §5.1): ``@rollmux.phase`` decorator,
run permits, warm-start state management, and runtime hooks.

The execution plane is in-process: resource pools are permit queues, job
states live in a HostStateCache between phases (device_put back = warm
start), and the intra-group FIFO queues drive the round-robin schedule.
"""
from __future__ import annotations

import functools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.train.checkpoints import HostStateCache


class PermitPool:
    """A resource pool (e.g. 'rollout', 'train') with FIFO run permits —
    the per-worker queue of §5.1."""

    def __init__(self, name: str, capacity: int = 1):
        self.name = name
        self.capacity = capacity
        self._cv = threading.Condition()
        self._queue: deque[int] = deque()
        self._active = 0
        self._ticket = 0
        self.busy_time = 0.0
        self.timeline: list[tuple[str, float, float]] = []  # (who, t0, t1)

    def acquire(self) -> int:
        with self._cv:
            self._ticket += 1
            my = self._ticket
            self._queue.append(my)
            while self._queue[0] != my or self._active >= self.capacity:
                self._cv.wait()
            self._queue.popleft()
            self._active += 1
            return my

    def release(self) -> None:
        with self._cv:
            self._active -= 1
            self._cv.notify_all()


@dataclass
class PhaseStats:
    runs: int = 0
    warm_starts: int = 0
    cold_starts: int = 0
    switch_time: float = 0.0
    run_time: float = 0.0
    wait_time: float = 0.0


class RollMuxRuntime:
    """In-process execution plane shared by the co-executing jobs."""

    def __init__(self, host_cache_gb: float = 64.0):
        self.pools: dict[str, PermitPool] = {}
        self.cache = HostStateCache(int(host_cache_gb * 2**30))
        self.stats: dict[str, PhaseStats] = {}
        self.hooks: list[Callable[[str, str, str], None]] = []
        self._t0 = time.perf_counter()

    def pool(self, name: str, capacity: int = 1) -> PermitPool:
        if name not in self.pools:
            self.pools[name] = PermitPool(name, capacity)
        return self.pools[name]

    def runtime_hook(self, fn: Callable) -> Callable:
        """@rollmux.runtime_hook — called as fn(job_id, phase, event)."""
        self.hooks.append(fn)
        return fn

    def _emit(self, job_id: str, phase_name: str, event: str) -> None:
        for h in self.hooks:
            h(job_id, phase_name, event)

    def phase(self, pool: str, name: Optional[str] = None, *,
              init_fn: Optional[Callable] = None):
        """Decorator: wraps a phase function into a schedulable entity.

        The wrapped function signature becomes fn(job_id, *args) and receives
        the job's restored state as first arg: fn(state, *args) -> (state, out).
        State is offloaded to host DRAM after the phase (lightweight
        suspension: the compiled executables — the control plane — stay
        alive, only data-plane arrays move).
        """
        def deco(fn):
            pname = name or fn.__name__

            @functools.wraps(fn)
            def wrapped(job_id: str, *args, **kwargs):
                key = f"{job_id}/{pool}"
                st = self.stats.setdefault(f"{job_id}:{pname}", PhaseStats())
                t_req = time.perf_counter()
                p = self.pool(pool)
                p.acquire()                       # run permit (intra-group FIFO)
                try:
                    t_start = time.perf_counter()
                    st.wait_time += t_start - t_req
                    self._emit(job_id, pname, "start")
                    state, sw = self.cache.restore(key)
                    if state is None:             # cold start
                        t0 = time.perf_counter()
                        if init_fn is None:
                            raise RuntimeError(
                                f"no cached state and no init_fn for {key}")
                        state = init_fn()
                        sw = time.perf_counter() - t0
                        st.cold_starts += 1
                    else:
                        st.warm_starts += 1
                    st.switch_time += sw
                    state, out = fn(state, *args, **kwargs)
                    jax.block_until_ready(jax.tree.leaves(state)[:1])
                    self.cache.offload(key, state)  # suspend: data plane out
                    t_end = time.perf_counter()
                    st.run_time += t_end - t_start
                    st.runs += 1
                    p.timeline.append((f"{job_id}:{pname}", t_start - self._t0,
                                       t_end - self._t0))
                    p.busy_time += t_end - t_start
                    self._emit(job_id, pname, "end")
                    return out
                finally:
                    p.release()

            wrapped.pool_name = pool
            wrapped.phase_name = pname
            return wrapped
        return deco

    def seed_state(self, job_id: str, pool: str, state) -> None:
        """Pre-populate the actor cache (Init phase of the dependency graph)."""
        self.cache.offload(f"{job_id}/{pool}", state)
