"""Inter-group scheduler — Algorithm 1 (paper §4.2).

Online, marginal-cost-minimizing placement with conservative (worst-case)
SLO admission, memory-residency constraints, and saturation pruning.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Optional

from repro.core.cluster import NodeAllocator
from repro.core.group import CoExecutionGroup, Placement
from repro.core.job import RLJob


@dataclass
class Decision:
    group: CoExecutionGroup
    placement: Placement
    delta_cost: float
    strategy: str            # "pack" | "scale_rollout" | "isolated"
    latency_s: float = 0.0


class InterGroupScheduler:
    def __init__(self, allocator: NodeAllocator, *, max_group_size: int = 5,
                 slo_check: bool = True, admission_margin: float = 0.93,
                 overload_tolerance: float = 1.10):
        # admission_margin < 1 reserves headroom for context-switch latency
        # and non-preemptive scheduling anomalies (realized phase times
        # shorter than the worst-case bound can reorder FIFO queues).
        # overload_tolerance: a placement may saturate the group slightly
        # (Fig 10a packs two identical jobs at load ~104% of cycle) but
        # never heavily (Fig 3/Fig 6: over-saturated groups are avoided).
        self.alloc = allocator
        self.groups: dict[str, CoExecutionGroup] = {}
        self._gid = itertools.count()
        self.max_group_size = max_group_size
        self.slo_check = slo_check
        self.admission_margin = admission_margin
        self.overload_tolerance = overload_tolerance
        self.decision_latencies: list[float] = []

    # ------------------------------------------------------------------
    def schedule(self, job: RLJob) -> Decision:
        t0 = time.perf_counter()
        best: Optional[tuple] = None  # (delta, tiebreak, G, placement, strategy, n_new)

        for G in self.groups.values():
            # line 4: prune (over-)saturated groups — no slack to absorb work
            if not G.jobs or G.t_load() > self.overload_tolerance * G.t_cycle():
                continue
            if len(G.jobs) >= self.max_group_size:     # residency-bounded size
                continue
            for placement, n_new, strategy in self._gen_placements(G, job):
                cand = self._evaluate(G, job, placement, n_new)
                if cand is None:
                    continue
                delta, tiebreak = cand
                key = (delta, tiebreak)
                if best is None or key < (best[0], best[1]):
                    best = (delta, tiebreak, G, placement, strategy, n_new)

        iso_delta = self._isolated_cost(job)
        lat = time.perf_counter() - t0
        self.decision_latencies.append(lat)

        if best is not None and best[0] < iso_delta:
            delta, _, G, placement, strategy, n_new = best
            if n_new:
                new_nodes = self.alloc.alloc_rollout(n_new)
                for n in new_nodes:
                    G.rollout_nodes[n.node_id] = n
                placement = Placement(tuple(n.node_id for n in new_nodes))
            G.add_job(job, placement)
            return Decision(G, placement, delta, strategy, lat)

        # fallback: isolated provisioning (line 15-17)
        G = self._new_group(job)
        placement = Placement(tuple(G.rollout_nodes))
        G.add_job(job, placement)
        return Decision(G, placement, iso_delta, "isolated", lat)

    # ------------------------------------------------------------------
    def _gen_placements(self, G: CoExecutionGroup, job: RLJob):
        """Direct packing (Δ=0) and rollout scaling (Δ=new rollout nodes)."""
        k = job.n_roll_nodes
        if len(G.rollout_nodes) >= k:
            # pack onto the k least-loaded rollout nodes
            load = {nid: 0.0 for nid in G.rollout_nodes}
            for jid, pl in G.placements.items():
                for nid in pl.rollout_node_ids:
                    load[nid] += G.jobs[jid].t_roll
            chosen = tuple(sorted(load, key=load.get)[:k])
            yield Placement(chosen), 0, "pack"
        yield Placement(()), k, "scale_rollout"   # nodes allocated on commit

    def _evaluate(self, G: CoExecutionGroup, job: RLJob,
                  placement: Placement, n_new: int):
        """Hypothetically admit; returns (delta_cost, tiebreak) or None."""
        added = []
        if n_new:
            # simulate fresh rollout nodes without touching the allocator
            accel = self.alloc.rollout_accel
            from repro.core.cluster import Node
            added = [Node(f"__tmp{i}", accel) for i in range(n_new)]
            for n in added:
                G.rollout_nodes[n.node_id] = n
            placement = Placement(tuple(n.node_id for n in added))
        try:
            if not G.fits_memory(job, placement):           # line 8
                return None
            G.add_job(job, placement)
            try:
                # Admitting may saturate the group slightly (Fig 10a packs
                # two identical jobs at load ~104% of cycle) but heavily
                # over-saturated placements are rejected (Fig 3 / Fig 6).
                if G.t_load() > self.overload_tolerance * G.t_cycle():
                    return None
                if self.slo_check and not G.slo_ok(
                        margin=self.admission_margin):      # line 10
                    return None
                delta = sum(n.price_per_hour for n in added)
                slack = G.t_load() / max(G.t_cycle(), 1e-9)
                return delta, slack
            finally:
                G.remove_job(job.job_id)
        finally:
            for n in added:
                G.rollout_nodes.pop(n.node_id, None)

    def _isolated_cost(self, job: RLJob) -> float:
        r = job.n_roll_nodes * self.alloc.rollout_accel.price_per_gpu_hour * 8
        t = job.n_train_nodes * self.alloc.train_accel.price_per_gpu_hour * 8
        return r + t

    def _new_group(self, job: RLJob) -> CoExecutionGroup:
        G = CoExecutionGroup(
            f"g{next(self._gid)}",
            self.alloc.alloc_rollout(job.n_roll_nodes),
            self.alloc.alloc_train(job.n_train_nodes))
        self.groups[G.gid] = G
        return G

    # ------------------------------------------------------------------
    def release(self, job_id: str) -> None:
        """Job departed: free nodes no longer pinned by anyone."""
        for gid, G in list(self.groups.items()):
            if job_id not in G.jobs:
                continue
            G.remove_job(job_id)
            if not G.jobs:
                self.alloc.release(list(G.rollout_nodes.values()))
                self.alloc.release(list(G.train_nodes.values()))
                del self.groups[gid]
            else:
                pinned = {nid for pl in G.placements.values()
                          for nid in pl.rollout_node_ids}
                loose = [n for nid, n in G.rollout_nodes.items()
                         if nid not in pinned]
                for n in loose:
                    del G.rollout_nodes[n.node_id]
                self.alloc.release(loose)
            return

    def total_cost_per_hour(self) -> float:
        return sum(G.cost_per_hour() for G in self.groups.values())

    # ------------------------------------------------------------------
    def slo_contract(self) -> dict[str, float]:
        """Export the per-job slowdown bounds admission has guaranteed:
        ``{job_id: bound}`` with ``bound = job.slo * admission_margin``
        (the margin the planner reserved for context-switch latency and
        stochastic draws is part of the promise, so the serving layer
        enforces the *tightened* bound too).

        This is the wire between planning and serving: the engine policy
        for a job's rollout traffic is
        ``SLOPolicy.from_contract(sched.slo_contract(), job_id)`` — the
        same bound ``slo_ok`` admitted against now orders and gates
        per-request admission inside the engine.
        """
        return {jid: G.slowdown_bound(jid, margin=self.admission_margin)
                for G in self.groups.values() for jid in G.jobs}
