"""Co-execution group (paper §4.1) + the intra-group round-robin schedule
(§4.3) as a discrete-event simulation.

The DES is used three ways:
  * admission control — worst-case durations, migration off (conservative);
  * at-scale trace replay — stochastic durations, migration on;
  * Theorem 1 checking — comparing round-robin against perturbed schedules.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.cluster import Node
from repro.core.job import RLJob

TRAIN_POOL = "__train__"
REWARD_POOL = "__reward__"


@dataclass(frozen=True)
class Placement:
    rollout_node_ids: tuple[str, ...]


@dataclass
class SimResult:
    iter_time: dict[str, float]          # steady-state per-job iteration time
    rollout_util: float                  # busy fraction of rollout nodes
    train_util: float
    rollout_bubble: float                # idle fraction (dependency bubbles)
    train_bubble: float
    makespan: float


@dataclass
class SwitchCosts:
    """Context-switch latencies (paper Fig 4). Warm = host-DRAM reload;
    cold = cross-cluster fetch / re-init."""
    warm_s: float = 1.6
    cold_s: float = 75.0


class CoExecutionGroup:
    def __init__(self, gid: str, rollout_nodes: list[Node],
                 train_nodes: list[Node]):
        self.gid = gid
        self.rollout_nodes: dict[str, Node] = {n.node_id: n for n in rollout_nodes}
        self.train_nodes: dict[str, Node] = {n.node_id: n for n in train_nodes}
        self.jobs: dict[str, RLJob] = {}
        self.placements: dict[str, Placement] = {}

    # ---- bookkeeping ---------------------------------------------------
    def add_job(self, job: RLJob, placement: Placement) -> None:
        self.jobs[job.job_id] = job
        self.placements[job.job_id] = placement

    def remove_job(self, job_id: str) -> None:
        self.jobs.pop(job_id, None)
        self.placements.pop(job_id, None)

    def cost_per_hour(self) -> float:
        return (sum(n.price_per_hour for n in self.rollout_nodes.values())
                + sum(n.price_per_hour for n in self.train_nodes.values()))

    # ---- saturation math (paper §4.2, Algorithm 1 line 4) ----------------
    def t_cycle(self) -> float:
        return max((j.t_solo for j in self.jobs.values()), default=0.0)

    def t_load(self) -> float:
        if not self.jobs:
            return 0.0
        pool = len(self.train_nodes)
        train_load = sum(j.train_time_on(pool) for j in self.jobs.values())
        node_load: dict[str, float] = {nid: 0.0 for nid in self.rollout_nodes}
        for jid, pl in self.placements.items():
            for nid in pl.rollout_node_ids:
                node_load[nid] += self.jobs[jid].t_roll
        roll_load = max(node_load.values(), default=0.0)
        return max(train_load, roll_load)

    def saturated(self) -> bool:
        return bool(self.jobs) and self.t_load() >= self.t_cycle()

    # ---- host-memory residency (paper C3) --------------------------------
    def node_mem_used(self) -> dict[str, float]:
        used = {nid: 0.0 for nid in (*self.rollout_nodes, *self.train_nodes)}
        for jid, pl in self.placements.items():
            j = self.jobs[jid]
            for nid in pl.rollout_node_ids:
                used[nid] += j.mem_roll_gb
            for nid in self.train_nodes:
                used[nid] += j.mem_train_gb
        return used

    def fits_memory(self, job: RLJob, placement: Placement) -> bool:
        used = self.node_mem_used()
        for nid in placement.rollout_node_ids:
            if used.get(nid, 0.0) + job.mem_roll_gb > self.rollout_nodes[nid].host_mem_gb:
                return False
        for nid, node in self.train_nodes.items():
            if used.get(nid, 0.0) + job.mem_train_gb > node.host_mem_gb:
                return False
        return True

    # ---- intra-group DES (paper §4.3) -------------------------------------
    def simulate(self, *, n_cycles: int = 14, discard: int = 4,
                 migration: bool = False, migration_overhead_frac: float = 0.02,
                 stochastic: bool = False, seed_salt: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 switch: Optional[SwitchCosts] = None,
                 order: Optional[list[str]] = None,
                 extra_phases: Optional[dict[str, int]] = None,
                 job_atomic: bool = False,
                 work_conserving: bool = False) -> SimResult:
        """Intra-group schedule DES, two modes:

        * strict round-robin meta-iteration (default) — the paper's §4.3
          abstraction and Theorem 1 setting. Start times are max-plus
          recurrences, monotone in durations, so worst-case admission
          bounds runtime (no non-preemptive scheduling anomalies). Used
          for admission control and the theory checker.
        * ``work_conserving=True`` — the paper's §5.1 runtime hooks: a
          phase is enqueued the moment its predecessor finishes and each
          resource serves the earliest-startable request (FIFO). Short
          jobs iterate faster than the meta-iteration bound; this is what
          the execution plane actually does and what the replay uses.

        ``rng=None`` -> deterministic worst-case durations (admission mode).
        ``extra_phases`` repeats a job's phases k extra times per cycle —
        only used by the Theorem 1 checker to show repetition is suboptimal.
        ``job_atomic`` models job-granular schedulers (Gavel+): the rollout
        and training phases run as one block holding both pools.
        """
        if not self.jobs:
            return SimResult({}, 0.0, 0.0, 1.0, 1.0, 0.0)
        jids = order or list(self.jobs)
        free: dict[str, float] = {nid: 0.0 for nid in self.rollout_nodes}
        free[TRAIN_POOL] = 0.0
        # third pool: reward verification (paper's streaming mux).  Jobs
        # with t_reward == 0 never touch it, so classic two-pool groups
        # simulate exactly as before.
        free[REWARD_POOL] = 0.0
        last_user: dict[str, Optional[str]] = {k: None for k in free}
        resident: set[tuple[str, str]] = set()
        pool = len(self.train_nodes)

        reps = {j: 1 + (extra_phases or {}).get(j, 0) for j in jids}
        ready = {j: 0.0 for j in jids}
        completions: dict[str, list[float]] = {j: [] for j in jids}
        busy = {k: 0.0 for k in free}

        def draw(jid: str) -> float:
            """Runtime-duration scale. Stochastic mode draws ONE static scale
            per job (common random numbers: identical whether the job is
            simulated solo or in any group), matching the paper's simulation
            setup (Table 6 durations are per-job draws); the admission
            planner's worst-case bound (scale=1) then provably covers it.
            Intra-phase straggler stochasticity is modeled separately via
            t80_frac (long-tail migration)."""
            job = self.jobs[jid]
            if rng is not None:
                lo, hi = job.runtime_scale
                return float(rng.uniform(lo, hi))
            if not stochastic:
                return 1.0
            ss = np.random.SeedSequence(
                [zlib.crc32(jid.encode()) & 0x7FFFFFFF, seed_salt])
            lo, hi = job.runtime_scale
            return float(np.random.default_rng(ss).uniform(lo, hi))

        # Strict cyclic round-robin (the paper's meta-iteration): every
        # resource serves phases in a FIXED (cycle, rr-order) sequence.
        # Start times are then max-plus recurrences, monotone in phase
        # durations — runtime draws <= the worst-case bound can never
        # reorder the schedule, which is what makes conservative admission
        # a real guarantee (no non-preemptive scheduling anomalies).
        def switch_cost(j, nodes) -> float:
            if switch is None:
                return 0.0
            sw = 0.0
            for n in nodes:
                if last_user[n] not in (None, j):
                    sw = max(sw, switch.warm_s if (j, n) in resident
                             else switch.cold_s)
            return sw

        def run_phase(j, kind, scale):
            """Execute one phase for job j at the earliest start; returns end."""
            job = self.jobs[j]
            if job_atomic:
                nodes = (*self.placements[j].rollout_node_ids, TRAIN_POOL)
                dur = (job.t_roll + job.t_reward
                       + job.train_time_on(pool)) * scale
                occupy = dur
            elif kind == "roll":
                nodes = self.placements[j].rollout_node_ids
                dur = job.t_roll * scale
                occupy = (dur * job.t80_frac + dur * migration_overhead_frac
                          if migration else dur)
            elif kind == "reward":
                nodes = (REWARD_POOL,)
                dur = job.t_reward * scale
                occupy = dur
            else:
                nodes = (TRAIN_POOL,)
                dur = job.train_time_on(pool) * scale
                occupy = dur
            start = max(ready[j], max(free[n] for n in nodes))
            sw = switch_cost(j, nodes)
            for n in nodes:
                free[n] = start + sw + occupy
                busy[n] += sw + occupy
                last_user[n] = j
                resident.add((j, n))
            ready[j] = start + sw + dur
            return ready[j]

        if work_conserving:
            # greedy FIFO: at each step dispatch the earliest-startable phase
            todo = {j: n_cycles * reps[j] for j in jids}
            phase = {j: "roll" for j in jids}
            t_end = 0.0
            while any(v > 0 for v in todo.values()):
                best, best_key = None, None
                for j in jids:
                    if todo[j] <= 0:
                        continue
                    if job_atomic:
                        nodes = (*self.placements[j].rollout_node_ids,
                                 TRAIN_POOL)
                    elif phase[j] == "roll":
                        nodes = self.placements[j].rollout_node_ids
                    elif phase[j] == "reward":
                        nodes = (REWARD_POOL,)
                    else:
                        nodes = (TRAIN_POOL,)
                    start = max(ready[j], max(free[n] for n in nodes))
                    key = (start, ready[j])
                    if best_key is None or key < best_key:
                        best, best_key = j, key
                j = best
                end = run_phase(j, phase[j], draw(j))
                if job_atomic or phase[j] == "train":
                    todo[j] -= 1
                    completions[j].append(end)
                    phase[j] = "roll"
                elif phase[j] == "roll" and self.jobs[j].t_reward > 0:
                    phase[j] = "reward"
                else:
                    phase[j] = "train"
                t_end = max(t_end, end)
            return self._summarize(jids, reps, completions, busy, t_end,
                                   discard)

        t_end = 0.0
        for cycle in range(n_cycles):
            for j in jids:
                job = self.jobs[j]
                for _ in range(reps[j]):
                    scale = draw(j)
                    if job_atomic:
                        nodes = (*self.placements[j].rollout_node_ids,
                                 TRAIN_POOL)
                        start = max(ready[j], max(free[n] for n in nodes))
                        sw = switch_cost(j, nodes)
                        dur = (job.t_roll + job.t_reward
                               + job.train_time_on(pool)) * scale
                        for n in nodes:
                            free[n] = start + sw + dur
                            busy[n] += sw + dur
                            last_user[n] = j
                            resident.add((j, n))
                        ready[j] = start + sw + dur
                        completions[j].append(ready[j])
                        t_end = max(t_end, ready[j])
                        continue
                    # rollout phase
                    nodes = self.placements[j].rollout_node_ids
                    start = max(ready[j], max(free[n] for n in nodes))
                    sw = switch_cost(j, nodes)
                    dur = job.t_roll * scale
                    occupy = dur
                    if migration:
                        occupy = (dur * job.t80_frac
                                  + dur * migration_overhead_frac)
                    for n in nodes:
                        free[n] = start + sw + occupy
                        busy[n] += sw + occupy
                        last_user[n] = j
                        resident.add((j, n))
                    ready[j] = start + sw + dur
                    # reward-verification phase (third pool; skipped when
                    # the job's verifier is modeled as inline/free)
                    if job.t_reward > 0:
                        start = max(ready[j], free[REWARD_POOL])
                        sw = switch_cost(j, (REWARD_POOL,))
                        dur = job.t_reward * scale
                        free[REWARD_POOL] = start + sw + dur
                        busy[REWARD_POOL] += sw + dur
                        last_user[REWARD_POOL] = j
                        resident.add((j, REWARD_POOL))
                        ready[j] = start + sw + dur
                    # training phase
                    start = max(ready[j], free[TRAIN_POOL])
                    sw = switch_cost(j, (TRAIN_POOL,))
                    dur = job.train_time_on(pool) * scale
                    free[TRAIN_POOL] = start + sw + dur
                    busy[TRAIN_POOL] += sw + dur
                    last_user[TRAIN_POOL] = j
                    resident.add((j, TRAIN_POOL))
                    ready[j] = start + sw + dur
                    completions[j].append(ready[j])
                    t_end = max(t_end, ready[j])

        return self._summarize(jids, reps, completions, busy, t_end, discard)

    def _summarize(self, jids, reps, completions, busy, t_end,
                   discard) -> SimResult:
        iter_time = {}
        for j in jids:
            cs = completions[j][discard * reps[j]:]
            if len(cs) >= 2:
                iter_time[j] = (cs[-1] - cs[0]) / (len(cs) - 1) * reps[j]
            else:
                iter_time[j] = self.jobs[j].t_solo
        roll_busy = sum(busy[n] for n in self.rollout_nodes)
        roll_total = max(t_end, 1e-9) * max(len(self.rollout_nodes), 1)
        train_busy = busy[TRAIN_POOL]
        return SimResult(
            iter_time=iter_time,
            rollout_util=roll_busy / roll_total,
            train_util=train_busy / max(t_end, 1e-9),
            rollout_bubble=1.0 - roll_busy / roll_total,
            train_bubble=1.0 - train_busy / max(t_end, 1e-9),
            makespan=t_end)

    # ---- SLO check used by the inter-group scheduler ----------------------
    def slo_ok(self, *, n_cycles: int = 14, margin: float = 1.0) -> bool:
        """Conservative admission: worst-case durations, migration off.
        ``margin`` < 1 tightens the target to absorb runtime stochasticity
        (straggler draws of co-members) and context-switch latency."""
        res = self.simulate(n_cycles=n_cycles)
        return all(res.iter_time[j] <= self.jobs[j].slo * margin
                   * self.jobs[j].t_solo + 1e-6 for j in self.jobs)

    # ---- the contract the serving layer enforces --------------------------
    def slowdown_bound(self, job_id: Optional[str] = None,
                       *, margin: float = 1.0) -> float:
        """The slowdown this group's admission *guaranteed* a job: worst-case
        iteration time stays within ``slowdown_bound * t_solo`` (that is
        what :meth:`slo_ok` checked before the job was admitted).

        This is the number the serving engine's ``SLOPolicy`` consumes
        (``repro.serve.sched``): per-request deadlines of
        ``arrival + bound * est_solo_latency`` turn the planner's per-job
        promise into an admission rule the rollout engine enforces under
        contention.  Without ``job_id`` the group's *tightest* bound is
        returned — the constraint every co-executed request must respect
        for no co-member's promise to break.
        """
        if job_id is not None:
            return self.jobs[job_id].slo * margin
        return min((j.slo for j in self.jobs.values()), default=1.0) * margin
