"""RollMux core: the paper's scheduling contribution."""
from repro.core.cluster import (H20, H800, V5E, GPUS_PER_NODE, HOST_MEM_GB,
                                AcceleratorType, Node, NodeAllocator)
from repro.core.job import RLJob, from_profile
from repro.core.group import (CoExecutionGroup, Placement, SimResult,
                              SwitchCosts)
from repro.core.inter_group import Decision, InterGroupScheduler
from repro.core.baselines import (GavelPlus, GreedyMostIdle, RandomScheduler,
                                  SoloDisaggregation, VeRLColocated,
                                  offline_optimal_cost)
from repro.core.simulator import (ClusterSimulator, Report,
                                  group_from_profiles, replay_verl,
                                  simulate_profiles)
from repro.core.phase_control import (PermitPool, PhaseProfile,
                                      RollMuxRuntime)
from repro.core.telemetry import MetricsSnapshot
from repro.core import distributions, theory, trace

__all__ = [
    "H20", "H800", "V5E", "GPUS_PER_NODE", "HOST_MEM_GB", "AcceleratorType",
    "Node", "NodeAllocator", "RLJob", "from_profile", "CoExecutionGroup",
    "Placement", "SimResult", "SwitchCosts", "Decision", "InterGroupScheduler",
    "GavelPlus", "GreedyMostIdle", "RandomScheduler", "SoloDisaggregation",
    "VeRLColocated", "offline_optimal_cost", "ClusterSimulator", "Report",
    "group_from_profiles", "replay_verl", "simulate_profiles", "PermitPool",
    "PhaseProfile", "RollMuxRuntime", "MetricsSnapshot", "distributions",
    "theory", "trace",
]
