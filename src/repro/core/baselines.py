"""Scheduler baselines from the paper's evaluation:
Solo-D, colocated veRL, Gavel+, Random, Greedy (most-idle), Offline-Optimal.
"""
from __future__ import annotations

import random as _random
from typing import Optional

from repro.core.cluster import Node, NodeAllocator
from repro.core.group import CoExecutionGroup, Placement
from repro.core.inter_group import Decision, InterGroupScheduler
from repro.core.job import RLJob


class SoloDisaggregation(InterGroupScheduler):
    """Standard disaggregation: every job gets a dedicated group (paper Fig 1-top)."""

    def schedule(self, job: RLJob) -> Decision:
        G = self._new_group(job)
        placement = Placement(tuple(G.rollout_nodes))
        G.add_job(job, placement)
        return Decision(G, placement, self._isolated_cost(job), "isolated")


class VeRLColocated:
    """Monolithic co-location on the training pool: rollout runs on H800 with
    a memory-bandwidth slowdown; no rollout pool is provisioned."""

    def __init__(self, allocator: NodeAllocator):
        self.alloc = allocator
        self.jobs: dict[str, tuple[RLJob, list[Node]]] = {}

    def rollout_slowdown(self) -> float:
        return (self.alloc.rollout_accel.hbm_tbps
                / self.alloc.train_accel.hbm_tbps)  # H20 4.0 / H800 3.35

    def schedule(self, job: RLJob):
        nodes = self.alloc.alloc_train(job.n_train_nodes)
        self.jobs[job.job_id] = (job, nodes)

    def iter_time(self, job: RLJob) -> float:
        return job.t_roll * self.rollout_slowdown() + job.t_train

    def release(self, job_id: str) -> None:
        _, nodes = self.jobs.pop(job_id, (None, []))
        self.alloc.release(nodes)

    def total_cost_per_hour(self) -> float:
        return sum(sum(n.price_per_hour for n in ns)
                   for _, ns in self.jobs.values())


class RandomScheduler(InterGroupScheduler):
    """Random feasible group (memory + size only — no SLO guarantee)."""

    def __init__(self, allocator, *, max_group_size=5, seed=0):
        super().__init__(allocator, max_group_size=max_group_size,
                         slo_check=False)
        self.rng = _random.Random(seed)

    def schedule(self, job: RLJob) -> Decision:
        cands = []
        for G in self.groups.values():
            if len(G.jobs) >= self.max_group_size or not G.jobs:
                continue
            if len(G.rollout_nodes) < job.n_roll_nodes:
                continue
            nids = self.rng.sample(list(G.rollout_nodes), job.n_roll_nodes)
            pl = Placement(tuple(nids))
            if G.fits_memory(job, pl):
                cands.append((G, pl))
        if cands and self.rng.random() < 0.5:
            G, pl = self.rng.choice(cands)
            G.add_job(job, pl)
            return Decision(G, pl, 0.0, "pack")
        G = self._new_group(job)
        pl = Placement(tuple(G.rollout_nodes))
        G.add_job(job, pl)
        return Decision(G, pl, self._isolated_cost(job), "isolated")


class GreedyMostIdle(InterGroupScheduler):
    """Most-idle group first, least-loaded nodes — no SLO guarantee."""

    def __init__(self, allocator, *, max_group_size=5):
        super().__init__(allocator, max_group_size=max_group_size,
                         slo_check=False)

    def schedule(self, job: RLJob) -> Decision:
        best = None
        for G in self.groups.values():
            if len(G.jobs) >= self.max_group_size or not G.jobs:
                continue
            if len(G.rollout_nodes) < job.n_roll_nodes:
                continue
            idle = 1.0 - G.t_load() / max(G.t_cycle(), 1e-9)
            load = {nid: 0.0 for nid in G.rollout_nodes}
            for jid, pl in G.placements.items():
                for nid in pl.rollout_node_ids:
                    load[nid] += G.jobs[jid].t_roll
            nids = tuple(sorted(load, key=load.get)[:job.n_roll_nodes])
            pl = Placement(nids)
            if not G.fits_memory(job, pl):
                continue
            if best is None or idle > best[0]:
                best = (idle, G, pl)
        if best is not None and best[0] > 0:
            _, G, pl = best
            G.add_job(job, pl)
            return Decision(G, pl, 0.0, "pack")
        G = self._new_group(job)
        pl = Placement(tuple(G.rollout_nodes))
        G.add_job(job, pl)
        return Decision(G, pl, self._isolated_cost(job), "isolated")


class GavelPlus(GreedyMostIdle):
    """Heterogeneity-aware job-level scheduler (Gavel + RL support): shares
    pools across jobs but multiplexes at *job* granularity — a job's
    rollout+train pair runs as one atomic block, so dependency bubbles
    inside the block are never reclaimed. Modeled via the job_atomic DES flag.
    """
    job_atomic = True


# ---------------------------------------------------------------------------
# Offline optimal (brute force over set partitions; small instances only)
# ---------------------------------------------------------------------------
def _partitions(items: list):
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in _partitions(rest):
        for i, block in enumerate(part):
            yield part[:i] + [[first] + block] + part[i + 1:]
        yield [[first]] + part


def _best_group_cost(jobs: list[RLJob], alloc: NodeAllocator,
                     max_group_size: int) -> Optional[float]:
    """Min provisioning cost of one SLO-feasible group for these jobs."""
    if len(jobs) > max_group_size:
        return None
    r_price = alloc.rollout_accel.price_per_gpu_hour * 8
    t_price = alloc.train_accel.price_per_gpu_hour * 8
    n_train = max(j.n_train_nodes for j in jobs)
    lo = max(j.n_roll_nodes for j in jobs)
    hi = sum(j.n_roll_nodes for j in jobs)
    for n_roll in range(lo, hi + 1):
        nodes_r = [Node(f"r{i}", alloc.rollout_accel) for i in range(n_roll)]
        nodes_t = [Node(f"t{i}", alloc.train_accel) for i in range(n_train)]
        G = CoExecutionGroup("opt", nodes_r, nodes_t)
        # LPT bin packing of rollout load onto nodes
        load = {n.node_id: 0.0 for n in nodes_r}
        ok = True
        for j in sorted(jobs, key=lambda j: -j.t_roll):
            nids = sorted(load, key=load.get)[:j.n_roll_nodes]
            pl = Placement(tuple(nids))
            if not G.fits_memory(j, pl):
                ok = False
                break
            G.add_job(j, pl)
            for nid in nids:
                load[nid] += j.t_roll
        if not ok:
            continue
        if G.saturated() or not G.slo_ok():
            continue
        return n_roll * r_price + n_train * t_price
    return None


def offline_optimal_cost(jobs: list[RLJob], alloc: NodeAllocator,
                         max_group_size: int = 5) -> float:
    """Brute-force minimum total $/h over all partitions (paper §7.5 'Opt')."""
    best = float("inf")
    for part in _partitions(list(jobs)):
        total = 0.0
        feasible = True
        for block in part:
            c = _best_group_cost(block, alloc, max_group_size)
            if c is None:
                feasible = False
                break
            total += c
        if feasible:
            best = min(best, total)
    return best
