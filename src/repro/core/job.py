"""RL post-training job model consumed by the RollMux schedulers."""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.cluster import GPUS_PER_NODE


@dataclass
class RLJob:
    job_id: str
    # worst-case phase durations (conservative planning, paper §4.2):
    t_roll: float             # rollout phase on its rollout nodes (s)
    t_train: float            # training phase on its requested train nodes (s)
    # reward-verification phase on the reward pool (s); 0 models the
    # classic inline-verified loop (reward folded into training), > 0 the
    # streaming mux's third pool where external verifiers take real time
    t_reward: float = 0.0
    n_roll_gpus: int = 8
    n_train_gpus: int = 8
    mem_roll_gb: float = 275.0    # host footprint per rollout node (Table 2)
    mem_train_gb: float = 240.0
    slo: float = 2.0              # tolerated slowdown vs solo (paper: Unif(1,2))
    arrival: float = 0.0
    duration: float = 3600.0      # trace job lifetime (s)
    # runtime stochasticity: actual phase times = worst-case * Unif draw
    runtime_scale: tuple[float, float] = (0.5, 1.0)
    # long-tail rollout shape: fraction of phase at which 80% of responses done
    t80_frac: float = 0.6
    model: str = ""
    turns: str = "single"

    @property
    def t_solo(self) -> float:
        """Back-to-back solo iteration: rollout, then (when modeled)
        reward verification, then the train step."""
        return self.t_roll + self.t_reward + self.t_train

    @property
    def n_roll_nodes(self) -> int:
        return max(1, self.n_roll_gpus // GPUS_PER_NODE)

    @property
    def n_train_nodes(self) -> int:
        return max(1, self.n_train_gpus // GPUS_PER_NODE)

    def train_time_on(self, pool_nodes: int) -> float:
        """Paper footnote 2: DP degree adapts to the group train pool size."""
        return self.t_train * self.n_train_nodes / max(pool_nodes, 1)


def from_profile(profile, job_id: str, *, slo: float = 2.0, arrival=0.0,
                 duration=3600.0) -> RLJob:
    """Build an RLJob from a configs.paper_jobs.JobProfile."""
    return RLJob(
        job_id=job_id, t_roll=profile.t_roll, t_train=profile.t_train,
        n_roll_gpus=profile.n_roll_gpus, n_train_gpus=profile.n_train_gpus,
        mem_roll_gb=profile.mem_roll_gb, mem_train_gb=profile.mem_train_gb,
        slo=slo, arrival=arrival, duration=duration, model=profile.model,
        turns=profile.turns)
