"""Cluster model: heterogeneous accelerator pools, nodes, pricing (paper Table 1)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass


@dataclass(frozen=True)
class AcceleratorType:
    name: str
    tflops: float           # bf16 compute
    hbm_gb: float
    hbm_tbps: float
    price_per_gpu_hour: float


# Paper Table 1 (H20 rollout pool / H800 training pool). The TPU-disaggregated
# analogue parameterizes the same fields (DESIGN.md §3).
H20 = AcceleratorType("H20", 148.0, 96.0, 4.0, 1.85)
H800 = AcceleratorType("H800", 989.5, 80.0, 3.35, 5.28)
# TPU stand-ins with the task-spec roofline constants
V5E = AcceleratorType("v5e", 197.0, 16.0, 0.819, 1.2)

GPUS_PER_NODE = 8
HOST_MEM_GB = 1536.0      # 1-2 TB high-memory nodes (paper C3)


@dataclass
class Node:
    node_id: str
    accel: AcceleratorType
    gpus: int = GPUS_PER_NODE
    host_mem_gb: float = HOST_MEM_GB

    @property
    def price_per_hour(self) -> float:
        return self.gpus * self.accel.price_per_gpu_hour


class NodeAllocator:
    """Hands out nodes from the two physical pools (328 + 328 GPUs default)."""

    def __init__(self, n_rollout_gpus: int = 328, n_train_gpus: int = 328,
                 rollout_accel: AcceleratorType = H20,
                 train_accel: AcceleratorType = H800,
                 elastic: bool = True):
        self.rollout_accel, self.train_accel = rollout_accel, train_accel
        self._ids = itertools.count()
        self.free_rollout = [Node(f"R{i}", rollout_accel)
                             for i in range(n_rollout_gpus // GPUS_PER_NODE)]
        self.free_train = [Node(f"T{i}", train_accel)
                           for i in range(n_train_gpus // GPUS_PER_NODE)]
        self.elastic = elastic          # allow exceeding physical pool (cloud)
        self.peak_rollout = 0
        self.peak_train = 0
        self._out_rollout: set[str] = set()
        self._out_train: set[str] = set()

    def _take(self, pool: list[Node], n: int, kind: str) -> list[Node]:
        if len(pool) < n:
            if not self.elastic:
                raise RuntimeError(f"{kind} pool exhausted")
            accel = self.rollout_accel if kind == "rollout" else self.train_accel
            for _ in range(n - len(pool)):
                pool.append(Node(f"{kind[0].upper()}x{next(self._ids)}", accel))
        out = [pool.pop() for _ in range(n)]
        return out

    def alloc_rollout(self, n_nodes: int) -> list[Node]:
        nodes = self._take(self.free_rollout, n_nodes, "rollout")
        self._out_rollout |= {n.node_id for n in nodes}
        self.peak_rollout = max(self.peak_rollout, len(self._out_rollout))
        return nodes

    def alloc_train(self, n_nodes: int) -> list[Node]:
        nodes = self._take(self.free_train, n_nodes, "train")
        self._out_train |= {n.node_id for n in nodes}
        self.peak_train = max(self.peak_train, len(self._out_train))
        return nodes

    def release(self, nodes: list[Node]) -> None:
        for n in nodes:
            if n.accel is self.train_accel and n.node_id in self._out_train:
                self._out_train.discard(n.node_id)
                self.free_train.append(n)
            elif n.node_id in self._out_rollout:
                self._out_rollout.discard(n.node_id)
                self.free_rollout.append(n)
