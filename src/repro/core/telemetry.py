"""Unified telemetry: one typed, mergeable snapshot of every metrics
surface in the stack.

Before this module the observability story was ad hoc: ``Engine`` exposed
an ``EngineStats`` record, ``DisaggRouter`` a stats facade that summed and
delegated, the radix tree its own hit/miss counter dict, and the runtime
per-phase ``PhaseStats``.  Consumers (benchmarks, the launchers, and now
the elastic controller) had to know which shape they were holding.

:class:`MetricsSnapshot` is the one shape.  ``Engine.metrics()``,
``DisaggRouter.metrics()`` and ``RollMuxRuntime.metrics()`` all return it;
snapshots from different components merge (:meth:`MetricsSnapshot.merge`)
by the obvious per-field rule — counters sum, peaks max, gauges from the
later/other snapshot win, dict-valued fields union.  The elastic
controller (``serve.elastic``) and the benchmarks consume *only* this API;
legacy attribute access (``engine.stats`` / ``router.stats``) survives via
a warn-once :class:`DeprecationWarning` shim (same pattern as the PR 8
``RolloutSpec`` migration).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, fields


def warn_legacy_once(flag: list, message: str) -> None:
    """Emit ``message`` as a :class:`DeprecationWarning` the first time the
    module-level ``flag`` (a one-element mutable list, so tests can reset
    it) is seen unset.  ``stacklevel=3`` points at the caller of the
    deprecated property, not the shim machinery."""
    if not flag[0]:
        flag[0] = True
        warnings.warn(message, DeprecationWarning, stacklevel=3)


@dataclass
class MetricsSnapshot:
    """One merged view of serving/runtime health at a point in time.

    Field classes (and their merge rule):

    * **counters** (sum): monotone totals — steps, prefills, transfers,
      sheds, … .
    * **peaks** (max): high-water marks — ``peak_active``,
      ``peak_kv_blocks``.
    * **gauges** (other wins when set): instantaneous occupancy —
      ``queue_depth``, ``num_active``, ``kv_blocks_in_use``, … .  Merging
      a router's decode + prefill snapshots sums these *before* they meet
      this rule (the router does that itself), so cross-component merges
      just keep the freshest reading.
    * **dicts** (union, other wins per key): per-pool busy fractions and
      capacities, per-class attainment.
    """

    source: str = ""

    # -- queueing / slot occupancy (gauges except the peaks/counters noted)
    queue_depth: int = 0                 # gauge: waiting requests
    rejected_submits: int = 0            # counter
    num_slots: int = 0                   # gauge: configured decode slots
    num_active: int = 0                  # gauge: live decode slots
    peak_active: int = 0                 # peak
    slot_steps: int = 0                  # counter: slot-steps with work

    # -- decode progress (counters)
    steps: int = 0
    decode_time_s: float = 0.0
    prefills: int = 0
    recorded_tokens: int = 0
    generated_tokens: int = 0

    # -- KV block pool
    kv_blocks_total: int = 0             # gauge: pool size
    kv_blocks_in_use: int = 0            # gauge
    peak_kv_blocks: int = 0              # peak

    # -- prefix sharing (counters; pinned_blocks is a gauge)
    prefix_hits: int = 0
    prefix_partial_hits: int = 0
    prefix_misses: int = 0
    prefix_evictions: int = 0
    prefix_snapshots: int = 0            # gauge: live boundary snapshots
    snapshot_demotions: int = 0          # counter: TTL demotions
    blocks_saved: int = 0
    pinned_blocks: int = 0               # gauge: radix-held blocks

    # -- suspend/resume + disaggregation
    adoptions: int = 0                   # counter
    suspends: int = 0                    # counter
    resumes: int = 0                     # counter
    suspended: int = 0                   # gauge: live suspended handles
    transfers: int = 0                   # counter
    transfer_time_s: float = 0.0         # counter
    transferred_blocks: int = 0          # counter
    transfer_backlog: int = 0            # gauge: handles awaiting adoption
    kv_routed: int = 0                   # counter

    # -- admission control (counters; attainment is a dict gauge)
    sheds: int = 0
    degrades: int = 0
    attainment: dict = field(default_factory=dict)   # class -> met fraction

    # -- runtime permit pools (dict gauges)
    pool_busy_frac: dict = field(default_factory=dict)
    pool_capacity: dict = field(default_factory=dict)

    weight_version: int = 0              # gauge

    _PEAKS = ("peak_active", "peak_kv_blocks")
    _GAUGES = ("queue_depth", "num_slots", "num_active", "kv_blocks_total",
               "kv_blocks_in_use", "prefix_snapshots", "pinned_blocks",
               "suspended", "transfer_backlog", "weight_version")
    _DICTS = ("attainment", "pool_busy_frac", "pool_capacity")

    # -- derived ------------------------------------------------------
    @property
    def time_per_token(self) -> float:
        """Mean decode step wall time (the SLO policy's EMA seed)."""
        return self.decode_time_s / max(self.steps, 1)

    @property
    def slot_utilization(self) -> float:
        """Useful tokens per slot-step of capacity offered (matches
        ``EngineStats.slot_utilization``)."""
        return self.generated_tokens / max(self.slot_steps, 1)

    @property
    def kv_block_utilization(self) -> float:
        return self.kv_blocks_in_use / max(self.kv_blocks_total, 1)

    @property
    def transfer_overhead_frac(self) -> float:
        """KV-transfer wall time as a fraction of transfer + decode time
        (zero when nothing was served)."""
        busy = self.transfer_time_s + self.decode_time_s
        if busy <= 0.0:
            return 0.0
        return self.transfer_time_s / busy

    @property
    def queue_pressure(self) -> float:
        """Waiting requests per configured slot — the controller's primary
        grow signal."""
        return self.queue_depth / max(self.num_slots, 1)

    @property
    def occupancy(self) -> float:
        """Live slots / configured slots — the controller's shrink signal."""
        return self.num_active / max(self.num_slots, 1)

    # -- merging ------------------------------------------------------
    def merge(self, other: "MetricsSnapshot") -> "MetricsSnapshot":
        """Per-field merge: counters sum, peaks max, gauges take ``other``
        when it carries a reading, dicts union with ``other`` winning per
        key.  Returns a new snapshot; neither input is mutated."""
        out = MetricsSnapshot(source=self.source or other.source)
        if self.source and other.source and other.source != self.source:
            out.source = f"{self.source}+{other.source}"
        for f in fields(self):
            if f.name == "source":
                continue
            a, b = getattr(self, f.name), getattr(other, f.name)
            if f.name in self._DICTS:
                setattr(out, f.name, {**a, **b})
            elif f.name in self._PEAKS:
                setattr(out, f.name, max(a, b))
            elif f.name in self._GAUGES:
                setattr(out, f.name, b if b else a)
            else:
                setattr(out, f.name, a + b)
        return out

    @classmethod
    def merged(cls, snapshots) -> "MetricsSnapshot":
        out = cls()
        for s in snapshots:
            out = out.merge(s)
        return out

    def to_dict(self) -> dict:
        """Flat dict (dataclass fields + the derived ratios) for JSON
        reports."""
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        d.update(time_per_token=self.time_per_token,
                 slot_utilization=self.slot_utilization,
                 kv_block_utilization=self.kv_block_utilization)
        return d
