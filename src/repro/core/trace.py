"""Workload/trace generation: paper Table 6 simulation profiles + a
Philly-like multi-tenant arrival trace (paper §7.5) + the two-week
production replay mix (§7.4)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.paper_jobs import MEM_FOOTPRINT_GB, SIM_PROFILES
from repro.core.job import RLJob

_SIZES = {"S": (8, 8), "M": (8, 8), "L": (16, 16)}
_MEM = {"S": "7B", "M": "14B", "L": "32B"}


def make_sim_job(rng: np.random.Generator, job_id: str, *,
                 workload: str = "Mixed", slo: Optional[float] = None,
                 arrival: float = 0.0, duration: float = 3600.0) -> RLJob:
    """Sample one job from paper Table 6 (BL/RH/TH x S/M/L, Unif bounds)."""
    wl = workload if workload != "Mixed" else rng.choice(["BL", "RH", "TH"])
    size = rng.choice(["S", "M", "L"])
    (rl, rh), (tl, th) = SIM_PROFILES[wl][size]
    t_roll = float(rng.uniform(rl, rh))
    t_train = float(rng.uniform(tl, th))
    n_r, n_t = _SIZES[size]
    mem = MEM_FOOTPRINT_GB[_MEM[size]]
    return RLJob(
        job_id=job_id, t_roll=t_roll, t_train=t_train,
        n_roll_gpus=n_r, n_train_gpus=n_t,
        mem_roll_gb=mem["rollout"], mem_train_gb=mem["train"],
        slo=float(slo if slo is not None else rng.uniform(1.0, 2.0)),
        arrival=arrival, duration=duration,
        t80_frac=float(rng.uniform(0.45, 0.75)),
        model=f"{wl}-{size}", turns="multi" if wl == "RH" else "single")


def philly_like_trace(n_jobs: int = 300, horizon_h: float = 580.0, *,
                      mean_duration_h: float = 14.4,
                      max_duration_h: float = 142.9,
                      workload: str = "Mixed",
                      slo: Optional[float] = None,
                      seed: int = 0) -> list[RLJob]:
    """Arrival pattern modeled on the Microsoft Philly trace segment the
    paper uses (300 jobs / 580 h, mean 14.4 h, max 142.9 h)."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.uniform(0, horizon_h * 3600.0, n_jobs))
    sigma = 1.1
    mu = np.log(mean_duration_h) - sigma ** 2 / 2
    durations = np.clip(rng.lognormal(mu, sigma, n_jobs), 0.2, max_duration_h)
    jobs = []
    for i in range(n_jobs):
        jobs.append(make_sim_job(
            rng, f"job{i}", workload=workload, slo=slo,
            arrival=float(arrivals[i]), duration=float(durations[i] * 3600.0)))
    return jobs


# The paper's Fig 2: production RL traffic concentrates on ~10 recurring
# workload types (model x dataset x interaction mode) with phase durations
# in the 50-900 s range and multi-turn rollouts 3-4x their training phases.
# (name, size, turns, t_roll, t_train, n_gpus)
PRODUCTION_JOB_TYPES = [
    ("math-7B[S]",   "7B",  "single", 180.0, 170.0, 8),
    ("math-14B[S]",  "14B", "single", 280.0, 255.0, 8),
    ("code-7B[S]",   "7B",  "single", 230.0, 190.0, 8),
    ("code-32B[S]",  "32B", "single", 430.0, 400.0, 16),
    ("rlhf-3B[S]",   "3B",  "single",  90.0, 110.0, 8),
    ("agent-8B[M]",  "8B",  "multi",  520.0, 200.0, 8),
    ("agent-14B[M]", "14B", "multi",  780.0, 230.0, 8),
    ("tool-8B[M]",   "8B",  "multi",  640.0, 170.0, 8),
    ("game-3B[M]",   "3B",  "multi",  350.0, 100.0, 8),
    ("swe-32B[M]",   "32B", "multi",  900.0, 260.0, 16),
]
_TYPE_POPULARITY = np.array([0.16, 0.12, 0.10, 0.06, 0.08,
                             0.14, 0.10, 0.10, 0.08, 0.06])


def production_replay_trace(n_jobs: int = 200, *, horizon_h: float = 336.0,
                            jitter: float = 0.10, seed: int = 1) -> list[RLJob]:
    """Two-week, 200-job production replay (paper §7.4): jobs drawn from the
    ~10 recurring workload types of Fig 2 (mean duration 27.9 h)."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.uniform(0, horizon_h * 3600.0, n_jobs))
    sigma = 0.9
    mu = np.log(27.9) - sigma ** 2 / 2
    durations = np.clip(rng.lognormal(mu, sigma, n_jobs), 0.5, horizon_h)
    kinds = rng.choice(len(PRODUCTION_JOB_TYPES), n_jobs, p=_TYPE_POPULARITY)
    jobs = []
    for i, k in enumerate(kinds):
        name, size, turns, t_roll, t_train, n = PRODUCTION_JOB_TYPES[k]
        mem = MEM_FOOTPRINT_GB[size]
        jobs.append(RLJob(
            job_id=f"prod{i}",
            t_roll=float(t_roll * rng.uniform(1 - jitter, 1 + jitter)),
            t_train=float(t_train * rng.uniform(1 - jitter, 1 + jitter)),
            n_roll_gpus=n, n_train_gpus=n,
            mem_roll_gb=mem["rollout"], mem_train_gb=mem["train"],
            slo=float(rng.uniform(1.0, 2.0)),
            arrival=float(arrivals[i]), duration=float(durations[i] * 3600.0),
            t80_frac=float(rng.uniform(0.45, 0.7)),
            model=name, turns=turns))
    return jobs
