"""Theorem 1 (utilization optimality of the round-robin meta-iteration) —
numeric checker used by tests and the scheduler-quality benchmark."""
from __future__ import annotations

import itertools

from repro.core.cluster import Node, H20, H800
from repro.core.group import CoExecutionGroup, Placement
from repro.core.job import RLJob


def make_group(t_rolls, t_trains, *, slo=10.0, n_roll_nodes=1) -> CoExecutionGroup:
    """Single-rollout-node, single-train-node group (the appendix setting)."""
    nodes_r = [Node(f"r{i}", H20) for i in range(n_roll_nodes)]
    nodes_t = [Node("t0", H800)]
    G = CoExecutionGroup("thm", nodes_r, nodes_t)
    for i, (tr, tt) in enumerate(zip(t_rolls, t_trains)):
        j = RLJob(f"j{i}", t_roll=float(tr), t_train=float(tt), slo=slo)
        G.add_job(j, Placement((nodes_r[i % n_roll_nodes].node_id,)))
    return G


def aggregate_utilization(G: CoExecutionGroup, **sim_kw) -> float:
    res = G.simulate(**sim_kw)
    return res.rollout_util + res.train_util


def check_theorem1(t_rolls, t_trains) -> dict:
    """For an unsaturated group: round-robin utilization >= any job-repetition
    schedule and >= any alternative ordering. Returns the measurements."""
    G = make_group(t_rolls, t_trains)
    assert not G.saturated(), "theorem applies to unsaturated groups only"
    base = aggregate_utilization(G, n_cycles=120, discard=30)
    jids = list(G.jobs)
    # (2) repetition is suboptimal
    rep_utils = []
    for j in jids:
        rep_utils.append(aggregate_utilization(
            G, n_cycles=120, discard=30, extra_phases={j: 1}))
    # orderings achieve at most the round-robin utilization
    order_utils = []
    for perm in itertools.islice(itertools.permutations(jids), 6):
        order_utils.append(aggregate_utilization(
            G, n_cycles=120, discard=30, order=list(perm)))
    return {
        "round_robin": base,
        "max_repetition": max(rep_utils) if rep_utils else 0.0,
        "max_order": max(order_utils) if order_utils else base,
    }
