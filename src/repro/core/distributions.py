"""Long-tailed rollout response-length model (paper Fig 11 / C2).

Generation lengths follow a heavy-tailed lognormal clipped at the job's max
token limit; a rollout phase's duration is set by its slowest response
(skewness bubbles) while most GPUs finish at the ~80th percentile.
"""
from __future__ import annotations

import numpy as np


def sample_response_fractions(rng: np.random.Generator, n: int,
                              sigma: float = 0.9,
                              clip_frac: float = 1.0) -> np.ndarray:
    """Per-response completion times as fractions of the max-token time."""
    x = rng.lognormal(mean=-1.2, sigma=sigma, size=n)
    return np.clip(x, 0.02, clip_frac)


def phase_profile(rng: np.random.Generator, n_responses: int = 256,
                  sigma: float = 0.9) -> tuple[float, float]:
    """Returns (t80_frac, t_max_frac): 80th-percentile and max completion
    fractions of the worst-case (max-token) duration."""
    fr = sample_response_fractions(rng, n_responses, sigma)
    return float(np.quantile(fr, 0.8)), float(fr.max())


def straggler_stats(rng: np.random.Generator, n: int = 256,
                    sigma: float = 0.9) -> dict:
    fr = sample_response_fractions(rng, n, sigma)
    return {
        "p50": float(np.quantile(fr, 0.5)),
        "p80": float(np.quantile(fr, 0.8)),
        "p99": float(np.quantile(fr, 0.99)),
        "max": float(fr.max()),
        # mean GPU idleness while waiting for stragglers (skewness bubble)
        "bubble_frac": float(1.0 - fr.mean() / fr.max()),
    }
